"""Deterministic SLO traffic simulator for the serving pipeline
(DESIGN.md §15).

Production graph-similarity serving is judged under *mixed-tenant*
traffic — interactive top-k lookups with tight deadlines sharing the
pipeline with bulk range-τ scans — not under the uniform batched
throughput loop of ``benchmarks/query_throughput.py``.  This module
generates such traffic **deterministically** (a seeded trace is a plain
JSON value, goldens live under ``tests/fixtures/traffic/``) and replays
it against an ``AsyncGraphQueryEngine``, reporting per-tenant latency
percentiles, goodput under each tenant's deadline SLO, and
partial-result rates.

Two arrival models:

* **open loop** — each tenant is a Poisson process at ``rate_qps``;
  arrivals are scheduled on the trace clock regardless of completions
  (queueing delay shows up as latency).  This is the load-test model:
  the offered load does not back off when the pipeline falls behind.
* **closed loop** — each tenant runs ``clients`` synchronous clients,
  each issuing its next query the moment the previous one resolves.
  This is the interactive model: concurrency, not rate, is fixed.

The trace pins *everything* random — arrival times, tenant interleave,
query graphs (a db index + perturbation seed, materialised at replay),
modality choice, τ/k/deadline draws — so two replays of one trace issue
byte-identical query streams and any metric drift is the engine's.
"""
from __future__ import annotations

import hashlib
import json
import threading
import time
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serve.errors import AdmissionError

__all__ = ["TenantSpec", "TraceQuery", "TrafficTrace", "TrafficReport",
           "generate_trace", "replay", "percentile", "tenant_weights"]


def tenant_weights(tenants: Sequence["TenantSpec"]) -> Dict[str, float]:
    """Admission-control weight map from the tenant specs — feed to
    ``AsyncGraphQueryEngine(tenant_weights=...)`` so shed-oldest victim
    choice respects the same shares the trace was generated with."""
    return {t.name: float(t.weight) for t in tenants}


@dataclass(frozen=True)
class TenantSpec:
    """One tenant's traffic shape.

    * ``weight`` — relative share when mixing tenants in one stream.
    * ``rate_qps`` — open-loop Poisson arrival rate.
    * ``clients`` / ``queries_per_client`` — closed-loop shape.
    * ``topk_frac`` — fraction of queries that are top-k (the rest are
      range-τ); top-k queries draw ``k`` from ``k_range`` and run with
      filter cap ``cap``, range queries draw τ from ``tau_range``.
    * ``deadline_s`` — per-query SLO deadline (None = best effort).
    * ``edits_range`` — perturbation edits applied to the base db graph
      when materialising the query (controls answer difficulty).
    """
    name: str
    weight: float = 1.0
    rate_qps: float = 50.0
    clients: int = 2
    queries_per_client: int = 8
    topk_frac: float = 0.0
    tau_range: Tuple[int, int] = (1, 3)
    k_range: Tuple[int, int] = (1, 5)
    cap: int = 4
    deadline_s: Optional[float] = None
    edits_range: Tuple[int, int] = (1, 2)


@dataclass(frozen=True)
class TraceQuery:
    """One scheduled query — everything needed to materialise and issue
    it, no randomness left."""
    t: float                     # arrival time (open) / issue order (closed)
    tenant: str
    client: int                  # closed-loop client lane (0 in open loop)
    base: int                    # db graph index the query perturbs
    edits: int
    qseed: int                   # perturbation seed
    kind: str                    # "range" | "topk"
    tau: int                     # range τ, or the top-k filter cap
    k: Optional[int]
    deadline_s: Optional[float]


@dataclass
class TrafficTrace:
    """A fully-determined schedule of queries plus its provenance."""
    mode: str                    # "open" | "closed"
    seed: int
    n_db: int
    tenants: List[TenantSpec]
    queries: List[TraceQuery]
    version: int = 1

    def to_json(self) -> dict:
        return {"version": self.version, "mode": self.mode,
                "seed": self.seed, "n_db": self.n_db,
                "tenants": [asdict(t) for t in self.tenants],
                "queries": [asdict(q) for q in self.queries]}

    @classmethod
    def from_json(cls, obj: dict) -> "TrafficTrace":
        tenants = [TenantSpec(**{**t,
                                 "tau_range": tuple(t["tau_range"]),
                                 "k_range": tuple(t["k_range"]),
                                 "edits_range": tuple(t["edits_range"])})
                   for t in obj["tenants"]]
        queries = [TraceQuery(**q) for q in obj["queries"]]
        return cls(mode=obj["mode"], seed=obj["seed"], n_db=obj["n_db"],
                   tenants=tenants, queries=queries,
                   version=obj.get("version", 1))

    def digest(self) -> str:
        """Canonical content hash — the replay test's identity check."""
        blob = json.dumps(self.to_json(), sort_keys=True,
                          separators=(",", ":"))
        return hashlib.sha256(blob.encode()).hexdigest()

    def materialise(self, db) -> List:
        """Regenerate every query graph from the db + pinned seeds."""
        from repro.graphs.generators import perturb_graph
        out = []
        for q in self.queries:
            rng = np.random.default_rng(q.qseed)
            out.append(perturb_graph(db[q.base % len(db)], q.edits, rng,
                                     db.n_vlabels, db.n_elabels))
        return out


def _draw_query(rng, spec: TenantSpec, n_db: int, t: float,
                client: int) -> TraceQuery:
    base = int(rng.integers(0, n_db))
    edits = int(rng.integers(spec.edits_range[0], spec.edits_range[1] + 1))
    qseed = int(rng.integers(0, 2 ** 31 - 1))
    if float(rng.random()) < spec.topk_frac:
        k = int(rng.integers(spec.k_range[0], spec.k_range[1] + 1))
        return TraceQuery(t=t, tenant=spec.name, client=client, base=base,
                          edits=edits, qseed=qseed, kind="topk",
                          tau=int(spec.cap), k=k,
                          deadline_s=spec.deadline_s)
    tau = int(rng.integers(spec.tau_range[0], spec.tau_range[1] + 1))
    return TraceQuery(t=t, tenant=spec.name, client=client, base=base,
                      edits=edits, qseed=qseed, kind="range", tau=tau,
                      k=None, deadline_s=spec.deadline_s)


def generate_trace(tenants: Sequence[TenantSpec], n_db: int, *,
                   mode: str = "open", duration_s: float = 1.0,
                   seed: int = 0) -> TrafficTrace:
    """Build a deterministic trace.  Open loop: per-tenant Poisson
    arrivals over ``duration_s`` (weights scale the rates).  Closed
    loop: per-tenant client lanes, ``queries_per_client`` each;
    ``duration_s`` is unused there — the wall clock is the pipeline's.
    One child generator per tenant keeps a tenant's stream invariant
    under changes to the rest of the mix."""
    if mode not in ("open", "closed"):
        raise ValueError(f"unknown traffic mode {mode!r}")
    root = np.random.default_rng(seed)
    streams = {t.name: np.random.default_rng(s)
               for t, s in zip(tenants, root.spawn(len(tenants)))}
    queries: List[TraceQuery] = []
    for spec in tenants:
        rng = streams[spec.name]
        if mode == "open":
            rate = spec.rate_qps * spec.weight
            t = 0.0
            while True:
                t += float(rng.exponential(1.0 / max(rate, 1e-9)))
                if t >= duration_s:
                    break
                queries.append(_draw_query(rng, spec, n_db, round(t, 6), 0))
        else:
            for c in range(spec.clients):
                for i in range(spec.queries_per_client):
                    queries.append(
                        _draw_query(rng, spec, n_db, float(i), c))
    # stable global order: arrival time, then (tenant, client) as the
    # deterministic tie-break
    queries.sort(key=lambda q: (q.t, q.tenant, q.client))
    return TrafficTrace(mode=mode, seed=seed, n_db=n_db,
                        tenants=list(tenants), queries=queries)


# ---- replay ----------------------------------------------------------------

def percentile(xs: Sequence[float], p: float) -> float:
    """Nearest-rank percentile (deterministic, no interpolation)."""
    if not xs:
        return float("nan")
    s = sorted(xs)
    idx = min(len(s) - 1, max(0, int(np.ceil(p / 100.0 * len(s))) - 1))
    return s[idx]


@dataclass
class _Obs:
    tenant: str
    kind: str
    latency_s: float
    deadline_s: Optional[float]
    partial: bool
    error: bool
    # per-query stage breakdown (DESIGN.md §17), zeros on error
    filter_s: float = 0.0
    lb_s: float = 0.0
    verify_s: float = 0.0
    queue_s: float = 0.0
    # typed admission rejection/shed (DESIGN.md §18): intentional load
    # shedding, reported separately from stage failures
    rejected: bool = False


@dataclass
class TrafficReport:
    """Replay outcome: per-tenant and overall SLO metrics.

    * ``p50_ms`` / ``p99_ms`` — completion latency percentiles (from
      issue to resolution, queueing included).
    * ``goodput_qps`` — completed, non-partial queries that met their
      deadline (when one was set), per wall-clock second.
    * ``partial_rate`` — fraction resolved as deadline partials.
    * ``slo_miss_rate`` — fraction that missed their deadline (partials
      and late completions both count; deadline-less queries never
      miss).
    """
    wall_s: float
    per_tenant: Dict[str, dict] = field(default_factory=dict)
    overall: dict = field(default_factory=dict)

    @staticmethod
    def _bucket(obs: List[_Obs], wall_s: float) -> dict:
        lat = [o.latency_s for o in obs if not o.error]
        good = [o for o in obs
                if not o.error and not o.partial
                and (o.deadline_s is None or o.latency_s <= o.deadline_s)]
        missed = [o for o in obs
                  if o.deadline_s is not None
                  and (o.error or o.partial
                       or o.latency_s > o.deadline_s)]
        n = len(obs)
        done = [o for o in obs if not o.error]
        nd = max(len(done), 1)
        return {
            "n": n,
            "n_topk": sum(o.kind == "topk" for o in obs),
            "p50_ms": round(percentile(lat, 50) * 1e3, 3),
            "p99_ms": round(percentile(lat, 99) * 1e3, 3),
            "goodput_qps": round(len(good) / max(wall_s, 1e-9), 2),
            "partial_rate": round(sum(o.partial for o in obs)
                                  / max(n, 1), 4),
            "slo_miss_rate": round(len(missed) / max(n, 1), 4),
            # intentional admission shedding is not a failure: it reports
            # separately so "errors" keeps meaning broken queries
            "rejected": sum(o.rejected for o in obs),
            "errors": sum(o.error and not o.rejected for o in obs),
            # mean stage time per completed query (DESIGN.md §17)
            "filter_ms": round(sum(o.filter_s for o in done) / nd * 1e3, 3),
            "lb_ms": round(sum(o.lb_s for o in done) / nd * 1e3, 3),
            "verify_ms": round(sum(o.verify_s for o in done) / nd * 1e3, 3),
            "queue_ms": round(sum(o.queue_s for o in done) / nd * 1e3, 3),
        }

    @classmethod
    def build(cls, obs: List[_Obs], wall_s: float) -> "TrafficReport":
        rep = cls(wall_s=round(wall_s, 4))
        rep.overall = cls._bucket(obs, wall_s)
        for name in sorted({o.tenant for o in obs}):
            rep.per_tenant[name] = cls._bucket(
                [o for o in obs if o.tenant == name], wall_s)
        return rep

    def to_json(self) -> dict:
        return {"wall_s": self.wall_s, "overall": self.overall,
                "per_tenant": self.per_tenant}


def _to_request(q: TraceQuery, graph):
    from repro.serve.graph_engine import GraphQuery
    if q.kind == "topk":
        return GraphQuery(graph, q.tau, top_k=q.k, deadline_s=q.deadline_s,
                          tenant=q.tenant)
    return GraphQuery(graph, q.tau, deadline_s=q.deadline_s,
                      tenant=q.tenant)


def replay(trace: TrafficTrace, pipe, db, *, speed: float = 1.0,
           timeout_s: float = 300.0) -> TrafficReport:
    """Drive ``pipe`` (an ``AsyncGraphQueryEngine``) with the trace and
    measure.  ``speed`` compresses the open-loop schedule (2.0 = issue
    twice as fast); closed loop ignores it.  Latency is measured from
    issue to ticket resolution on the resolving thread."""
    graphs = trace.materialise(db)
    obs: List[_Obs] = []
    obs_lock = threading.Lock()

    def record(q: TraceQuery, t_issue: float, res, err) -> None:
        lat = time.perf_counter() - t_issue
        partial = bool(res is not None and res.stats.get("partial"))
        filter_s = lb_s = verify_s = queue_s = 0.0
        if res is not None:
            filter_s = float(res.filter_time_s)
            verify_s = float(res.verify_time_s)
            lb_s = float(res.stats.get("lb_s", 0.0))
            queue_s = float(res.stats.get("queue_s", 0.0))
        with obs_lock:
            obs.append(_Obs(q.tenant, q.kind, lat, q.deadline_s, partial,
                            err is not None, filter_s, lb_s, verify_s,
                            queue_s,
                            rejected=isinstance(err, AdmissionError)))

    t_start = time.perf_counter()
    if trace.mode == "open":
        for q, g in zip(trace.queries, graphs):
            target = t_start + q.t / max(speed, 1e-9)
            delay = target - time.perf_counter()
            if delay > 0:
                time.sleep(delay)
            t_issue = time.perf_counter()
            pipe.submit(_to_request(q, g))._add_callback(
                lambda res, err, q=q, ti=t_issue: record(q, ti, res, err))
        pipe.drain(timeout_s)
    else:
        lanes: Dict[Tuple[str, int], List[Tuple[TraceQuery, object]]] = {}
        for q, g in zip(trace.queries, graphs):
            lanes.setdefault((q.tenant, q.client), []).append((q, g))

        def run_lane(items) -> None:
            for q, g in items:
                t_issue = time.perf_counter()
                ticket = pipe.submit(_to_request(q, g))
                try:
                    res = ticket.result(timeout_s)
                    record(q, t_issue, res, None)
                except Exception as e:       # noqa: BLE001 — count, go on
                    record(q, t_issue, None, e)

        threads = [threading.Thread(target=run_lane, args=(items,),
                                    daemon=True)
                   for items in lanes.values()]
        for th in threads:
            th.start()
        for th in threads:
            th.join(timeout_s)
    wall = time.perf_counter() - t_start
    return TrafficReport.build(obs, wall)
