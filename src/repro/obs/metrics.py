"""Lock-safe metrics registry for the serving stack (DESIGN.md §17).

One ``MetricsRegistry`` per engine (and one per ``VerifyScheduler``):
monotonic counters, gauges, and fixed-bucket histograms behind a single
lock, cheap enough for the verifier hot loop — one uncontended
lock/bisect per observation, no allocation on the update path.

Three aggregation APIs make worker stats foldable into one view
regardless of where they were counted:

* ``snapshot()`` — a consistent plain-dict copy (safe to serialise,
  pickle across the process pool, or diff later);
* ``delta(new, old)`` — what happened *between* two snapshots
  (counters/histograms subtract, gauges keep the newer value);
* ``merge(a, b)`` — fold two snapshots into one (counters/histograms
  add, gauges take the max).  ``merge`` is associative and commutative
  on counters/histograms, so sync, async, process-pool, and
  sharded-subprocess paths can fold in any order.

``StatsView`` is the compatibility shim: a ``MutableMapping`` over one
registry namespace, so the pre-existing ``stats["verified_pairs"] += 1``
idiom (and every test that reads those keys) keeps working while the
numbers actually live in the registry.
"""
from __future__ import annotations

import bisect
import threading
from typing import Dict, Iterator, List, MutableMapping, Optional, Sequence

__all__ = ["DEFAULT_BUCKETS", "Histogram", "MetricsRegistry", "StatsView"]

# latency buckets in seconds (upper bounds; one implicit +inf overflow)
DEFAULT_BUCKETS = (
    1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0)


class Histogram:
    """Fixed-bucket histogram: counts per upper bound plus an overflow
    slot, total sum and count.  Mutated only by the owning registry,
    under its lock."""

    __slots__ = ("bounds", "counts", "sum", "count")

    def __init__(self, bounds: Sequence[float] = DEFAULT_BUCKETS):
        self.bounds = tuple(float(b) for b in bounds)
        self.counts = [0] * (len(self.bounds) + 1)
        self.sum = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        v = float(value)
        self.counts[bisect.bisect_left(self.bounds, v)] += 1
        self.sum += v
        self.count += 1

    def to_dict(self) -> dict:
        return {"bounds": list(self.bounds), "counts": list(self.counts),
                "sum": self.sum, "count": self.count}


class MetricsRegistry:
    """Counters / gauges / histograms behind one lock (DESIGN.md §17).

    Metric names are flat strings; ``view(namespace)`` scopes a
    ``StatsView`` to ``"<namespace>.<key>"`` names so independent
    components sharing a registry cannot collide.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[str, float] = {}    # guarded_by: self._lock
        self._gauges: Dict[str, float] = {}      # guarded_by: self._lock
        self._hists: Dict[str, Histogram] = {}   # guarded_by: self._lock

    # ---- counters ----------------------------------------------------------
    def counter_add(self, name: str, value=1) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + value

    def counter_set(self, name: str, value) -> None:
        """Absolute set — exists for the ``StatsView`` mapping shim; the
        callers that use it (``stats[k] += 1`` under their own outer
        lock) preserve monotonicity themselves."""
        with self._lock:
            self._counters[name] = value

    def counter_get(self, name: str, default=None):
        with self._lock:
            if name not in self._counters:
                if default is None:
                    raise KeyError(name)
                return default
            return self._counters[name]

    def counter_del(self, name: str) -> None:
        with self._lock:
            del self._counters[name]

    # ---- gauges ------------------------------------------------------------
    def gauge_set(self, name: str, value: float) -> None:
        with self._lock:
            self._gauges[name] = float(value)

    def gauge_get(self, name: str, default: float = 0.0) -> float:
        with self._lock:
            return self._gauges.get(name, default)

    # ---- histograms --------------------------------------------------------
    def observe(self, name: str, value: float,
                bounds: Sequence[float] = DEFAULT_BUCKETS) -> None:
        with self._lock:
            h = self._hists.get(name)
            if h is None:
                h = self._hists[name] = Histogram(bounds)
            h.observe(value)

    # ---- namespace helpers (the StatsView backend) -------------------------
    def ns_keys(self, prefix: str) -> List[str]:
        with self._lock:
            return [k[len(prefix):] for k in self._counters
                    if k.startswith(prefix)]

    def ns_snapshot(self, prefix: str) -> Dict[str, float]:
        """Consistent copy of one namespace's counters, prefix stripped —
        all keys read under a single lock acquisition."""
        with self._lock:
            return {k[len(prefix):]: v for k, v in self._counters.items()
                    if k.startswith(prefix)}

    # ---- aggregation -------------------------------------------------------
    def snapshot(self) -> dict:
        """A consistent plain-dict copy of everything: pickles across the
        process pool, serialises into trace artifacts, diffs/merges with
        the static helpers below."""
        with self._lock:
            return {"counters": dict(self._counters),
                    "gauges": dict(self._gauges),
                    "hists": {k: h.to_dict()
                              for k, h in self._hists.items()}}

    def absorb(self, snap: dict) -> None:
        """Fold a worker snapshot (or a ``delta``) into this registry:
        counters/histogram counts add, gauges take the max."""
        hists = snap.get("hists", {})
        with self._lock:
            for k, v in snap.get("counters", {}).items():
                self._counters[k] = self._counters.get(k, 0) + v
            for k, v in snap.get("gauges", {}).items():
                self._gauges[k] = max(self._gauges.get(k, v), v)
            for k, hd in hists.items():
                h = self._hists.get(k)
                if h is None:
                    h = self._hists[k] = Histogram(hd["bounds"])
                if tuple(hd["bounds"]) != h.bounds:
                    raise ValueError(
                        f"histogram {k!r}: bucket bounds differ")
                for i, c in enumerate(hd["counts"]):
                    h.counts[i] += c
                h.sum += hd["sum"]
                h.count += hd["count"]

    @staticmethod
    def merge(a: dict, b: dict) -> dict:
        """Fold two snapshots: counters/histograms add, gauges max.
        Associative and commutative, so any fold order over worker
        snapshots produces the same totals."""
        out = {"counters": dict(a.get("counters", {})),
               "gauges": dict(a.get("gauges", {})),
               "hists": {k: {**h, "bounds": list(h["bounds"]),
                             "counts": list(h["counts"])}
                         for k, h in a.get("hists", {}).items()}}
        for k, v in b.get("counters", {}).items():
            out["counters"][k] = out["counters"].get(k, 0) + v
        for k, v in b.get("gauges", {}).items():
            out["gauges"][k] = max(out["gauges"].get(k, v), v)
        for k, hd in b.get("hists", {}).items():
            h = out["hists"].get(k)
            if h is None:
                out["hists"][k] = {**hd, "bounds": list(hd["bounds"]),
                                   "counts": list(hd["counts"])}
                continue
            if list(hd["bounds"]) != list(h["bounds"]):
                raise ValueError(f"histogram {k!r}: bucket bounds differ")
            h["counts"] = [x + y for x, y in zip(h["counts"],
                                                 hd["counts"])]
            h["sum"] += hd["sum"]
            h["count"] += hd["count"]
        return out

    @staticmethod
    def delta(new: dict, old: dict) -> dict:
        """What happened between two snapshots of the *same* registry:
        counters/histograms subtract (missing old keys count from 0),
        gauges keep the newer value."""
        out = {"counters": {}, "gauges": dict(new.get("gauges", {})),
               "hists": {}}
        oldc = old.get("counters", {})
        for k, v in new.get("counters", {}).items():
            out["counters"][k] = v - oldc.get(k, 0)
        oldh = old.get("hists", {})
        for k, hd in new.get("hists", {}).items():
            oh = oldh.get(k)
            if oh is None:
                out["hists"][k] = {**hd, "bounds": list(hd["bounds"]),
                                   "counts": list(hd["counts"])}
                continue
            out["hists"][k] = {
                "bounds": list(hd["bounds"]),
                "counts": [x - y for x, y in zip(hd["counts"],
                                                 oh["counts"])],
                "sum": hd["sum"] - oh["sum"],
                "count": hd["count"] - oh["count"]}
        return out

    def view(self, namespace: str,
             initial: Optional[Dict[str, float]] = None) -> "StatsView":
        return StatsView(self, namespace, initial)


class StatsView(MutableMapping):
    """A dict-shaped window onto one registry namespace (DESIGN.md §17).

    Drop-in for the ad-hoc ``stats`` dicts the serving stack grew up
    with: ``view["verified_pairs"] += 1``, ``dict(view)``,
    ``view.get(k, 0)`` all behave as before, but every key lives in the
    registry as ``"<namespace>.<key>"`` so one snapshot/merge pass sees
    the whole system.  ``+=`` is read-then-write (two lock trips), which
    matches the old dict's discipline: every pre-existing mutation site
    already serialises under its component's outer lock.
    """

    __slots__ = ("_reg", "_prefix")

    def __init__(self, registry: MetricsRegistry, namespace: str,
                 initial: Optional[Dict[str, float]] = None):
        self._reg = registry
        self._prefix = namespace + "."
        if initial:
            for k, v in initial.items():
                registry.counter_set(self._prefix + k, v)

    @property
    def registry(self) -> MetricsRegistry:
        return self._reg

    def __getitem__(self, key: str):
        return self._reg.counter_get(self._prefix + key)

    def __setitem__(self, key: str, value) -> None:
        self._reg.counter_set(self._prefix + key, value)

    def __delitem__(self, key: str) -> None:
        self._reg.counter_del(self._prefix + key)

    def __iter__(self) -> Iterator[str]:
        return iter(self._reg.ns_keys(self._prefix))

    def __len__(self) -> int:
        return len(self._reg.ns_keys(self._prefix))

    def __repr__(self) -> str:
        return f"StatsView({self.snapshot()!r})"

    def snapshot(self) -> Dict[str, float]:
        """Consistent copy under one lock acquisition — what
        ``stats_snapshot()`` callers should hand out."""
        return self._reg.ns_snapshot(self._prefix)
