"""Per-stage health state machine for the degradation ladder.

Each fallible serving stage (device filter backend, slab decode,
verifier process pool) owns a :class:`StageHealth` tracking

    HEALTHY --fail x fail_threshold--> FAILING --probe ok--> HEALTHY
       \\--fail--> DEGRADED --ok--> HEALTHY

* ``HEALTHY``  — use the primary path.
* ``DEGRADED`` — recent failure(s); primary still attempted.
* ``FAILING``  — ``fail_threshold`` consecutive failures; the primary
  is *sticky-skipped* and only re-attempted as a probe every
  ``probe_interval`` calls (sticky-until-probe recovery, DESIGN.md
  §18).  One successful probe restores HEALTHY.

State changes are mirrored into a ``MetricsRegistry`` when one is
attached (``health.<stage>`` gauge: 0 healthy / 1 degraded / 2
failing, plus failure/probe counters), so ladder decisions are visible
in the same snapshot as the serving stats.
"""
from __future__ import annotations

import threading
from typing import Optional

from repro.obs.metrics import MetricsRegistry

HEALTHY, DEGRADED, FAILING = "healthy", "degraded", "failing"
_CODE = {HEALTHY: 0, DEGRADED: 1, FAILING: 2}


class StageHealth:
    """Thread-safe tri-state health tracker with probe-based recovery."""

    def __init__(self, stage: str, *, fail_threshold: int = 3,
                 probe_interval: int = 8,
                 registry: Optional[MetricsRegistry] = None) -> None:
        if fail_threshold < 1 or probe_interval < 1:
            raise ValueError("fail_threshold and probe_interval are >= 1")
        self.stage = stage
        self.fail_threshold = fail_threshold
        self.probe_interval = probe_interval
        self._lock = threading.Lock()
        self._state = HEALTHY
        self._consec_failures = 0
        self._calls_since_trip = 0
        self._registry: Optional[MetricsRegistry] = None
        self.attach(registry)

    # ------------------------------------------------------------------
    def attach(self, registry: Optional[MetricsRegistry]) -> None:
        """(Re)bind the metrics registry and publish current state."""
        with self._lock:
            self._registry = registry
            self._publish_locked()

    def _publish_locked(self) -> None:
        if self._registry is not None:
            self._registry.gauge_set(f"health.{self.stage}",
                                     _CODE[self._state])

    def _count(self, name: str) -> None:
        if self._registry is not None:
            self._registry.counter_add(f"health.{self.stage}.{name}")

    # ------------------------------------------------------------------
    @property
    def state(self) -> str:
        with self._lock:
            return self._state

    def allow_primary(self) -> bool:
        """Should this call attempt the primary path?

        True while HEALTHY/DEGRADED.  While FAILING, True only on every
        ``probe_interval``-th call (the probe); otherwise the caller
        goes straight to its fallback without paying the failure."""
        with self._lock:
            if self._state != FAILING:
                return True
            self._calls_since_trip += 1
            if self._calls_since_trip >= self.probe_interval:
                self._calls_since_trip = 0
                self._count("probes")
                return True
            return False

    def record_success(self) -> None:
        with self._lock:
            self._consec_failures = 0
            if self._state != HEALTHY:
                self._state = HEALTHY
                self._count("recoveries")
                self._publish_locked()

    def record_failure(self) -> None:
        with self._lock:
            self._consec_failures += 1
            self._count("failures")
            prev = self._state
            if self._consec_failures >= self.fail_threshold:
                self._state = FAILING
                self._calls_since_trip = 0
            else:
                self._state = DEGRADED
            if self._state != prev:
                self._publish_locked()

    def snapshot(self) -> dict:
        with self._lock:
            return {"stage": self.stage, "state": self._state,
                    "consec_failures": self._consec_failures}
