"""Observability for the serving stack (DESIGN.md §17): one
``MetricsRegistry`` + one bounded ``SpanRecorder`` per engine, bundled
as an ``Observability`` object, with a threadlocal ambient context so
library layers (``core.engine``) record spans without threading an
``obs`` argument through the ``CandidateSource`` protocol.

Spans default **off** — every engine gets a registry (the ``stats``
views need one) but span recording costs nothing unless requested:

    eng = GraphQueryEngine(flat, obs=Observability(spans=True))
    ...
    eng.obs.export_trace("query.trace.json")
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

from repro.obs.metrics import (DEFAULT_BUCKETS, Histogram, MetricsRegistry,
                               StatsView)
from repro.obs.spans import Span, SpanRecorder

__all__ = ["DEFAULT_BUCKETS", "Histogram", "MetricsRegistry", "StatsView",
           "Span", "SpanRecorder", "Observability", "current_obs",
           "use_obs", "device_annotation"]


class Observability:
    """One engine's metrics registry + span ring (DESIGN.md §17)."""

    def __init__(self, *, spans: bool = False, span_capacity: int = 65536,
                 metrics: Optional[MetricsRegistry] = None):
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.spans = SpanRecorder(capacity=span_capacity, enabled=spans)

    def span(self, name: str, *, qid=None, **args):
        return self.spans.span(name, qid=qid, **args)

    def export_trace(self, path: str) -> str:
        from repro.obs.export import write_trace
        return write_trace(path, self)


_tl = threading.local()


def current_obs() -> Optional[Observability]:
    """The ambient ``Observability`` set by ``use_obs`` on this thread
    (None outside any engine's filter pass)."""
    return getattr(_tl, "obs", None)


@contextlib.contextmanager
def use_obs(obs: Optional[Observability]):
    """Make ``obs`` the ambient context for the with-block.  The serving
    engine wraps its filter stage in this so ``core.engine`` records
    bucket / filter / assign_lb spans without an API change; restores
    the previous context on exit (re-entrant)."""
    prev = getattr(_tl, "obs", None)
    _tl.obs = obs
    try:
        yield obs
    finally:
        _tl.obs = prev


def device_annotation(name: str):
    """Optional ``jax.profiler`` bracket: when the ambient obs has spans
    enabled, returns a ``TraceAnnotation`` so a device profile collected
    alongside lines the per-bucket ``pallas_call`` up with host spans;
    otherwise (or with no usable jax.profiler) a null context."""
    obs = current_obs()
    if obs is None or not obs.spans.enabled:
        return contextlib.nullcontext()
    try:
        import jax
        return jax.profiler.TraceAnnotation(name)
    except Exception:           # profiler unavailable: never break serving
        return contextlib.nullcontext()
