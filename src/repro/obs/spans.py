"""Structured per-query spans in a bounded ring buffer (DESIGN.md §17).

A ``Span`` is a closed host-time interval ``[t0, t1]`` (both from
``time.perf_counter()``) with a stage name, the logical thread it ran
on, an optional query id, and free-form args.  The serving stack records
one per pipeline stage — ``admission → encode → bucket → filter →
assign_lb → worklist → verify (per A* slice) → resolve`` plus ``queue``
and ``topk_round`` — so a single query's deadline budget can be read off
a trace instead of guessed from global counters.

``SpanRecorder`` is a deque ring under one lock: bounded (old spans
drop, ``dropped`` counts them), cheap (one lock trip per record, no
allocation beyond the Span), and disabled by default in production
engines — ``record()`` is a single attribute check when off, which is
what keeps the measured tracing overhead within the ≤2% budget.

``perf_counter`` is CLOCK_MONOTONIC (system-wide) on the Linux hosts
this runs on — the same property the scheduler's cross-process deadlines
already rely on — so span fragments recorded inside process-pool workers
land on the same timeline as host spans.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

__all__ = ["Span", "SpanRecorder"]


@dataclass(frozen=True)
class Span:
    """One recorded stage interval (host perf_counter seconds)."""
    name: str
    t0: float
    t1: float
    tid: str                       # logical thread (or pool worker) name
    qid: Optional[int] = None      # engine-assigned query id, if any
    args: Dict = field(default_factory=dict)

    @property
    def dur(self) -> float:
        return self.t1 - self.t0


class SpanRecorder:
    """Bounded ring of ``Span``s shared by every pipeline stage."""

    def __init__(self, capacity: int = 65536, enabled: bool = True):
        self.enabled = bool(enabled)
        self.capacity = int(capacity)
        self._lock = threading.Lock()
        self._ring: "deque[Span]" = \
            deque(maxlen=self.capacity)       # guarded_by: self._lock
        self._dropped = 0                     # guarded_by: self._lock

    def record(self, name: str, t0: float, t1: float, *,
               qid: Optional[int] = None, tid: Optional[str] = None,
               **args) -> None:
        """Record one closed interval.  Callers time with their own
        ``perf_counter`` reads (usually already taken for the stats
        counters) so recording never adds a clock call to the hot path
        beyond what the stage measured anyway."""
        if not self.enabled:
            return
        if tid is None:
            tid = threading.current_thread().name
        span = Span(name, float(t0), float(t1), tid, qid, args)
        with self._lock:
            if len(self._ring) == self.capacity:
                self._dropped += 1
            self._ring.append(span)

    @contextmanager
    def span(self, name: str, *, qid: Optional[int] = None, **args):
        """Context-manager sugar for stages without pre-taken timestamps."""
        if not self.enabled:
            yield
            return
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.record(name, t0, time.perf_counter(), qid=qid, **args)

    def extend(self, spans) -> None:
        """Fold span fragments from elsewhere (process-pool workers,
        subprocess shards) onto this ring."""
        if not self.enabled:
            return
        with self._lock:
            for s in spans:
                if len(self._ring) == self.capacity:
                    self._dropped += 1
                self._ring.append(s)

    def spans(self) -> List[Span]:
        with self._lock:
            return list(self._ring)

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()
            self._dropped = 0

    @property
    def dropped(self) -> int:
        with self._lock:
            return self._dropped

    def __len__(self) -> int:
        with self._lock:
            return len(self._ring)

    def aggregate(self) -> Dict[str, Tuple[int, float]]:
        """Per-stage ``name -> (count, total seconds)`` over the ring —
        the per-stage breakdown table in ``examples/serve_requests.py``."""
        out: Dict[str, Tuple[int, float]] = {}
        for s in self.spans():
            n, tot = out.get(s.name, (0, 0.0))
            out[s.name] = (n + 1, tot + s.dur)
        return out
