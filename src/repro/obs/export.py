"""Trace export: Chrome trace-event / Perfetto JSON (DESIGN.md §17).

``to_trace_events`` maps recorded ``Span``s onto the trace-event format
(``ph: "X"`` complete events, microsecond ``ts``/``dur``, integer
pid/tid plus ``"M"`` ``thread_name`` metadata events naming the logical
threads), loadable in ``chrome://tracing`` / https://ui.perfetto.dev.
``write_trace`` bundles the events with a full metrics snapshot in
``otherData`` so one artifact carries both views; ``spans_from_trace``
round-trips events back into ``Span``s (the exporter test's identity
check) and ``validate_trace`` is the bench-smoke schema gate.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

from repro.obs.spans import Span

__all__ = ["to_trace_events", "write_trace", "load_trace",
           "spans_from_trace", "validate_trace"]

_PID = 1                     # one serving process per trace artifact


def to_trace_events(spans: List[Span]) -> List[dict]:
    """Spans -> trace events.  Logical thread names map to stable small
    integer tids (first appearance order) and each gets a ``thread_name``
    metadata event, so Perfetto lanes read ``apipe-verify-0`` instead of
    bare numbers."""
    tids: Dict[str, int] = {}
    events: List[dict] = []
    for s in spans:
        if s.tid not in tids:
            tids[s.tid] = len(tids) + 1
            events.append({"name": "thread_name", "ph": "M", "pid": _PID,
                           "tid": tids[s.tid],
                           "args": {"name": s.tid}})
        args = dict(s.args)
        if s.qid is not None:
            args["qid"] = s.qid
        events.append({"name": s.name, "cat": "serve", "ph": "X",
                       "ts": s.t0 * 1e6, "dur": (s.t1 - s.t0) * 1e6,
                       "pid": _PID, "tid": tids[s.tid], "args": args})
    return events


def write_trace(path: str, obs) -> str:
    """Write one trace artifact: events + metrics snapshot + ring stats."""
    obj = {"traceEvents": to_trace_events(obs.spans.spans()),
           "displayTimeUnit": "ms",
           "otherData": {"metrics": obs.metrics.snapshot(),
                         "dropped_spans": obs.spans.dropped}}
    with open(path, "w", encoding="utf-8") as f:
        json.dump(obj, f)
    return path


def load_trace(path: str) -> dict:
    with open(path, encoding="utf-8") as f:
        return json.load(f)


def spans_from_trace(obj: dict) -> List[Span]:
    """Rebuild ``Span``s from a trace object (thread names resolved from
    the metadata events; µs back to seconds)."""
    names: Dict[int, str] = {}
    for ev in obj["traceEvents"]:
        if ev.get("ph") == "M" and ev.get("name") == "thread_name":
            names[ev["tid"]] = ev["args"]["name"]
    out: List[Span] = []
    for ev in obj["traceEvents"]:
        if ev.get("ph") != "X":
            continue
        args = dict(ev.get("args", {}))
        qid: Optional[int] = args.pop("qid", None)
        t0 = ev["ts"] / 1e6
        out.append(Span(ev["name"], t0, t0 + ev["dur"] / 1e6,
                        names.get(ev["tid"], str(ev["tid"])), qid, args))
    return out


def validate_trace(obj: dict) -> None:
    """Schema gate for bench-smoke: raises AssertionError on violation."""
    assert isinstance(obj.get("traceEvents"), list), "traceEvents missing"
    complete = 0
    for ev in obj["traceEvents"]:
        for fld in ("name", "ph", "pid", "tid"):
            assert fld in ev, f"event missing {fld!r}: {ev}"
        if ev["ph"] == "X":
            complete += 1
            assert "ts" in ev and "dur" in ev, f"X event lacks ts/dur: {ev}"
            assert ev["dur"] >= 0, f"negative duration: {ev}"
    assert complete > 0, "trace has no complete (ph='X') span events"
    metrics = obj.get("otherData", {}).get("metrics")
    assert isinstance(metrics, dict) and "counters" in metrics, \
        "otherData.metrics snapshot missing"
    assert isinstance(metrics["counters"], dict)
