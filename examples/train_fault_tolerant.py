"""Fault-tolerant LM training demo: reduced qwen3 config, injected
failures, async checkpoints, automatic restart-from-checkpoint, loss curve.

    PYTHONPATH=src python examples/train_fault_tolerant.py
"""
import tempfile

import jax

from repro.configs import get_config, reduced
from repro.data import ShardedLoader, StragglerSimulator, SyntheticLMDataset
from repro.models import build_params
from repro.optim import adamw, cosine_schedule
from repro.train import (FailureInjector, Trainer, TrainerConfig,
                         make_train_step)


def main() -> None:
    cfg = reduced(get_config("qwen3-1.7b"))
    params = build_params(cfg, jax.random.PRNGKey(0))
    opt_init, opt_update = adamw(cosine_schedule(3e-4, 10, 60))
    step = jax.jit(make_train_step(cfg, opt_update, microbatches=2))
    ds = SyntheticLMDataset(cfg.vocab_size, seq_len=32, global_batch=8)
    loader = ShardedLoader(ds, straggler_timeout_s=0.2,
                           straggler=StragglerSimulator(slow_every=13,
                                                        delay_s=1.0))
    with tempfile.TemporaryDirectory() as ckdir:
        trainer = Trainer(
            step, params, opt_init(params), loader,
            TrainerConfig(total_steps=60, checkpoint_every=10,
                          checkpoint_dir=ckdir, log_every=5),
            failure_injector=FailureInjector([17, 31, 32]))
        out = trainer.run()
    print(f"finished {out['final_step']} steps with {out['restarts']} "
          f"recoveries and {loader.reissues} straggler re-issues")
    for m in out["metrics"]:
        print(f"  step {m['step']:3d}  loss {m['loss']:.4f}  "
              f"|g| {m['grad_norm']:.2f}")
    first, last = out["metrics"][0]["loss"], out["metrics"][-1]["loss"]
    print(f"loss: {first:.3f} -> {last:.3f} "
          f"({'improved' if last < first else 'NOT improved'})")


if __name__ == "__main__":
    main()
