"""End-to-end serving driver for the paper's system: build a large index,
answer a batched query workload with filter-and-verify, report quality and
latency percentiles.  (The paper is a search-index paper, so the
end-to-end driver is the query service — assignment note.)

    PYTHONPATH=src python examples/index_search_e2e.py [--graphs 20000]
"""
import argparse
import time

import numpy as np

from repro.core.search import MSQIndex
from repro.core.verify import ged_upto
from repro.graphs.generators import aids_like_db, perturb_graph


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--graphs", type=int, default=20000)
    ap.add_argument("--queries", type=int, default=50)
    ap.add_argument("--tau", type=int, default=3)
    ap.add_argument("--verify-sample", type=int, default=200,
                    help="ground-truth sample size for recall audit")
    args = ap.parse_args()

    db = aids_like_db(args.graphs, seed=0)
    t0 = time.perf_counter()
    index = MSQIndex(db)
    print(f"index build: {time.perf_counter() - t0:.1f}s over "
          f"{args.graphs} graphs; "
          f"{index.size_bits()['total'] / 8 / 2**20:.2f} MiB")

    rng = np.random.default_rng(7)
    qids = rng.choice(args.graphs, args.queries, replace=False)
    queries = [perturb_graph(db[int(i)], 2, rng, db.n_vlabels, db.n_elabels)
               for i in qids]

    lat, cands, matches = [], [], []
    for h in queries:
        t0 = time.perf_counter()
        res = index.query(h, args.tau)
        lat.append(time.perf_counter() - t0)
        cands.append(len(res.candidates))
        matches.append(len(res.matches))
    lat_ms = np.array(lat) * 1e3
    print(f"tau={args.tau}: avg candidates {np.mean(cands):.1f} "
          f"({100 * np.mean(cands) / args.graphs:.3f}% of DB), "
          f"avg matches {np.mean(matches):.1f}")
    print(f"latency ms: p50={np.percentile(lat_ms, 50):.1f} "
          f"p90={np.percentile(lat_ms, 90):.1f} "
          f"p99={np.percentile(lat_ms, 99):.1f}")

    # recall audit on a random sample (filters are provably lossless; this
    # checks the implementation end to end)
    h = queries[0]
    sample = rng.choice(args.graphs, args.verify_sample, replace=False)
    res = index.query(h, args.tau)
    got = {gid for gid, _ in res.matches}
    missed = [int(g) for g in sample
              if ged_upto(db[int(g)], h, args.tau) <= args.tau
              and int(g) not in got]
    print(f"recall audit on {args.verify_sample} graphs: "
          f"{'PASS (no misses)' if not missed else f'MISSES: {missed}'}")


if __name__ == "__main__":
    main()
