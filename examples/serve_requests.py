"""Batched LM serving with the MSQ-Index as a retrieval pre-filter
(DESIGN.md §6c), now through the async pipelined engine (DESIGN.md §12):
each request carries a molecule graph; ``AsyncGraphQueryEngine`` forms
dynamic batches, runs the bucketed device filter pass while its verifier
pool drains earlier queries' GED worklists, and streams matches out
cheapest-first; retrieved ids condition the prompt; the LM decodes
batched.

    PYTHONPATH=src python examples/serve_requests.py
"""
import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core.search import MSQIndex
from repro.graphs.generators import aids_like_db, perturb_graph
from repro.models import build_params
from repro.obs import Observability
from repro.serve import (AsyncGraphQueryEngine, GraphQuery,
                         GraphQueryEngine, Request, ServeEngine,
                         as_completed)


def main() -> None:
    # retrieval side: molecule database + index + pipelined query engine,
    # with per-query span recording on (DESIGN.md §17)
    db = aids_like_db(1000, seed=2)
    index = MSQIndex(db)
    retriever = GraphQueryEngine(index, obs=Observability(spans=True))

    # serving side: small LM
    cfg = reduced(get_config("granite-moe-1b-a400m"))
    params = build_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, batch_size=4, max_len=64)

    rng = np.random.default_rng(0)
    mols = [perturb_graph(db[int(rng.integers(0, len(db)))], 2, rng,
                          db.n_vlabels, db.n_elabels) for _ in range(8)]
    with AsyncGraphQueryEngine(retriever, max_batch=4, max_delay_s=0.002,
                               num_workers=2) as apipe:
        # one verified request streams its matches as A* confirms them,
        # while the filter passes for the rest are still pipelining
        probe = apipe.submit(GraphQuery(mols[0], 1, verify=True))
        tickets = apipe.submit_many([GraphQuery(m, 3, verify=False)
                                     for m in mols])
        for gid, d in probe.stream(timeout=120):
            print(f"probe: streamed match graph {gid} at ged {d}")
        print(f"probe: {len(probe.result().candidates)} candidates, "
              f"{len(probe.result().matches)} matches "
              f"(stats {probe.result().stats})")
        retrieved = [None] * len(tickets)
        for i, res in as_completed(tickets, timeout=120):
            retrieved[i] = res        # arrive as their worklists finish
    requests = []
    for i, res in enumerate(retrieved):
        neighbours = res.candidates[:4]
        # prompt = [BOS=1] + retrieved neighbour ids folded into vocab
        prompt = np.array([1] + [2 + (g % (cfg.vocab_size - 2))
                                 for g in neighbours], np.int32)
        requests.append(Request(prompt=prompt, max_new_tokens=8))
        print(f"req{i}: |candidates|={len(res.candidates)} "
              f"-> prompt {prompt.tolist()}")
    print(f"retrieval: {retriever.stats['filter_s']:.3f}s filter for "
          f"{retriever.stats['queries']} queries "
          f"(backend={retriever.backend})")
    # per-stage breakdown from the recorded spans (DESIGN.md §17)
    print("stage breakdown (spans):")
    print(f"  {'stage':<14} {'count':>6} {'total_ms':>9}")
    for name, (count, total_s) in sorted(
            retriever.obs.spans.aggregate().items(),
            key=lambda kv: -kv[1][1]):
        print(f"  {name:<14} {count:>6} {total_s * 1e3:>9.2f}")
    engine.run(requests)
    for i, r in enumerate(requests):
        print(f"req{i}: generated {r.out_tokens}")
    print(f"prefill {engine.stats['prefill_s']:.2f}s, "
          f"decode {engine.stats['decode_s']:.2f}s, "
          f"{engine.stats['tokens']} tokens")


if __name__ == "__main__":
    main()
