"""Batched LM serving with the MSQ-Index as a retrieval pre-filter
(DESIGN.md §6c): each request carries a molecule graph; the batched
``GraphQueryEngine`` retrieves every request's GED neighbourhood in ONE
bucketed filter pass; retrieved ids condition the prompt; the LM decodes
batched.

    PYTHONPATH=src python examples/serve_requests.py
"""
import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core.search import MSQIndex
from repro.graphs.generators import aids_like_db, perturb_graph
from repro.models import build_params
from repro.serve import GraphQuery, GraphQueryEngine, Request, ServeEngine


def main() -> None:
    # retrieval side: molecule database + index + batched query engine
    db = aids_like_db(1000, seed=2)
    index = MSQIndex(db)
    retriever = GraphQueryEngine(index)

    # serving side: small LM
    cfg = reduced(get_config("granite-moe-1b-a400m"))
    params = build_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, batch_size=4, max_len=64)

    rng = np.random.default_rng(0)
    mols = [perturb_graph(db[int(rng.integers(0, len(db)))], 2, rng,
                          db.n_vlabels, db.n_elabels) for _ in range(8)]
    # one batched retrieval pass for all 8 requests
    retrieved = retriever.submit([GraphQuery(m, 3, verify=False)
                                  for m in mols])
    requests = []
    for i, res in enumerate(retrieved):
        neighbours = res.candidates[:4]
        # prompt = [BOS=1] + retrieved neighbour ids folded into vocab
        prompt = np.array([1] + [2 + (g % (cfg.vocab_size - 2))
                                 for g in neighbours], np.int32)
        requests.append(Request(prompt=prompt, max_new_tokens=8))
        print(f"req{i}: |candidates|={len(res.candidates)} "
              f"-> prompt {prompt.tolist()}")
    print(f"retrieval: {retriever.stats['filter_s']:.3f}s filter for "
          f"{retriever.stats['queries']} queries "
          f"(backend={retriever.backend})")
    engine.run(requests)
    for i, r in enumerate(requests):
        print(f"req{i}: generated {r.out_tokens}")
    print(f"prefill {engine.stats['prefill_s']:.2f}s, "
          f"decode {engine.stats['decode_s']:.2f}s, "
          f"{engine.stats['tokens']} tokens")


if __name__ == "__main__":
    main()
