"""Multi-device MSQ-Index search: the graph-sharded + vocab-sharded (TP)
filter pipeline on a simulated 8-device mesh — first the raw single-query
shard_map step, then the batched ``ShardedGraphQueryEngine`` answering a
whole mixed-tau request batch (DESIGN.md §10).

    PYTHONPATH=src python examples/distributed_search.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import jax
import jax.numpy as jnp
import numpy as np


def single_query_step(db, flat):
    """The dry-run unit: one query through the shard_map'd filter step."""
    from repro.core import filters_jax as fj
    from repro.core import jax_compat as jc
    from repro.core.distributed import (gather_candidates, make_sharded_search,
                                        pad_db_to_shards, pad_vocab)

    part = flat.partition
    dbar = fj.db_arrays_from_encoded(flat.enc, part)
    print(f"DB: {len(db)} graphs; dense F_D is "
          f"{dbar.fd.shape} ({dbar.fd.nbytes / 2**20:.1f} MiB)")

    mesh = jc.make_mesh((2, 4), ("data", "model"))
    print(f"mesh: {dict(mesh.shape)} over {len(jax.devices())} devices")

    rng = np.random.default_rng(3)
    from repro.graphs.generators import perturb_graph
    h = perturb_graph(db[99], 2, rng, db.n_vlabels, db.n_elabels)
    tau = 3
    q = fj.query_arrays_from_graph(h, flat.vocab, part, tau,
                                   vmax=dbar.degseq.shape[1])
    dbp, qp = pad_vocab(pad_db_to_shards(dbar, 2), q, 4)
    fn, _, _ = make_sharded_search(mesh, part.x0, part.y0, part.l, k=256,
                                   batch_axes=("data",), model_axis="model")
    with jc.set_mesh(mesh):
        args = (jax.tree.map(jnp.asarray, dbp), jax.tree.map(jnp.asarray, qp))
        gids, bnds, cnts = fn(*args)           # compile
        t0 = time.perf_counter()
        for _ in range(10):
            gids, bnds, cnts = fn(*args)
        jax.block_until_ready(gids)
        dt = (time.perf_counter() - t0) / 10
    cand = gather_candidates(np.asarray(gids), np.asarray(bnds),
                             np.asarray(cnts))
    ref = flat.candidates(h, tau)
    print(f"sharded filter: {dt * 1e3:.2f} ms/query, "
          f"{len(cand)} candidates; matches flat oracle: "
          f"{cand.tolist() == ref}")
    return mesh


def batched_engine(db, flat, mesh) -> None:
    """The serving path: a 32-query mixed-tau batch through the sharded
    engine in both layouts, parity-checked against the single-host engine."""
    from repro.core.search import FlatMSQIndex
    from repro.graphs.generators import perturb_graph
    from repro.launch.shardings import serving_specs
    from repro.serve.graph_engine import (GraphQuery, GraphQueryEngine,
                                          ShardedGraphQueryEngine)

    rng = np.random.default_rng(4)
    reqs = []
    for _ in range(32):
        tau = int(rng.integers(1, 4))
        h = perturb_graph(db[int(rng.integers(0, len(db)))], tau, rng,
                          db.n_vlabels, db.n_elabels)
        reqs.append(GraphQuery(h, tau, verify=False))

    single = GraphQueryEngine(flat, backend="numpy")
    ref = single.submit(reqs)

    # sharding layout x FilterSlab layout (DESIGN.md §11); packed slabs
    # have no vocab dim to split over 'model', so they stay graph-sharded
    for layout, slab in (("graph", "dense"), ("vocab", "dense"),
                         ("vocab", "hot"), ("graph", "packed")):
        db_sh, _, _, extra_sh = serving_specs(mesh, layout, slab=slab)
        print(f"layout {layout!r}/{slab!r}: F_D sharded {db_sh.fd.spec}, "
              f"{len(jax.tree.leaves(extra_sh))} slab extras")
        eng = ShardedGraphQueryEngine(FlatMSQIndex(db), mesh, layout=layout,
                                      slab_layout=slab, hot_d=32,
                                      result_cache_size=0)
        eng.submit(reqs)                       # warm (compiles per shape)
        t0 = time.perf_counter()
        out = eng.submit(reqs)
        dt = time.perf_counter() - t0
        ok = all(a.candidates == b.candidates for a, b in zip(out, ref))
        print(f"engine [{layout:5s}/{slab:6s}]: {len(reqs)} queries in "
              f"{dt * 1e3:.1f} ms ({len(reqs) / dt:.0f} q/s); identical to "
              f"single-host: {ok}; blocks={eng.shard_stats}")


def main() -> None:
    from repro.core.search import FlatMSQIndex
    from repro.graphs.generators import aids_like_db

    db = aids_like_db(4096, seed=0)
    flat = FlatMSQIndex(db)
    mesh = single_query_step(db, flat)
    batched_engine(db, flat, mesh)


if __name__ == "__main__":
    main()
