"""Quickstart: build an MSQ-Index, run a similarity query, verify results.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.search import MSQIndex
from repro.core.verify import ged_upto
from repro.graphs.generators import aids_like_db, perturb_graph


def main() -> None:
    # 1. a molecule-like graph database (AIDS-statistics synthetic)
    db = aids_like_db(2000, seed=0)
    print(f"database: {db.stats()}")

    # 2. build the index: region partition + succinct q-gram trees
    index = MSQIndex(db, l=4, block=16)
    sizes = index.size_bits()
    plain = index.plain_size_bits()
    print(f"built in {index.build_time_s:.2f}s; "
          f"T_SQ = {sizes['total'] / 8 / 1024:.1f} KiB "
          f"({100 * sizes['total'] / plain['total']:.1f}% of the "
          f"uncompressed q-gram tree)")

    # 3. query: find all graphs within GED tau of a perturbed member
    rng = np.random.default_rng(1)
    h = perturb_graph(db[123], 2, rng, db.n_vlabels, db.n_elabels)
    tau = 3
    res = index.query(h, tau)
    print(f"tau={tau}: {len(res.candidates)} candidates out of {len(db)} "
          f"graphs ({res.n_filtered} filtered), "
          f"{len(res.matches)} true matches")
    print(f"filter {res.filter_time_s * 1e3:.1f} ms, "
          f"verify {res.verify_time_s * 1e3:.1f} ms")
    for gid, d in res.matches[:5]:
        print(f"  graph {gid}: ged = {d}")

    # 4. spot-check against direct GED computation
    for gid, d in res.matches[:3]:
        assert ged_upto(db[gid], h, tau) == d
    print("verified against direct A* GED: OK")


if __name__ == "__main__":
    main()
