#!/usr/bin/env python
"""repro-lint CLI — see src/repro/analysis/ and DESIGN.md §14.

    python scripts/lint.py                 # full suite (make lint)
    python scripts/lint.py --select DOC    # doc citations (make check-docs)
    python scripts/lint.py --list-rules
"""
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))

from repro.analysis.runner import main  # noqa: E402

if __name__ == "__main__":
    argv = sys.argv[1:]
    if not any(a.startswith("--root") for a in argv):
        argv += ["--root", _ROOT]
    sys.exit(main(argv))
