#!/usr/bin/env bash
# Tier-1 check with hang protection: every test gets a per-test SIGALRM
# budget (tests/conftest.py reads REPRO_TEST_TIMEOUT) and the whole run a
# hard wall-clock cap, so a wedged test fails fast instead of hanging CI.
set -euo pipefail
cd "$(dirname "$0")/.."

PER_TEST_TIMEOUT="${REPRO_TEST_TIMEOUT:-120}"
TOTAL_TIMEOUT="${REPRO_TOTAL_TIMEOUT:-1500}"

export REPRO_TEST_TIMEOUT="$PER_TEST_TIMEOUT"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# static gate (DESIGN.md §14): lock discipline, JAX hygiene, Pallas
# contracts, and the doc-citation check — must be clean before tests run
python scripts/lint.py

exec timeout --signal=INT --kill-after=30 "$TOTAL_TIMEOUT" \
    python -m pytest -q "$@"
