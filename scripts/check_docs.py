#!/usr/bin/env python
"""DESIGN.md citation gate — legacy entry point.

The check now lives in the lint framework (``repro.analysis.docs``,
DESIGN.md §14); this shim is ``python scripts/lint.py --select DOC`` so
``make check-docs`` and old muscle memory keep working.
"""
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))

from repro.analysis.runner import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main(["--select", "DOC", "--root", _ROOT]))
