#!/usr/bin/env python
"""Fail if any `DESIGN.md §N` citation in the code dangles.

Modules cite DESIGN.md sections by number (e.g. ``DESIGN.md §5``); this
PR-gate greps the tree for those citations and checks that DESIGN.md has a
heading for every cited section, so the doc and the code can never drift
apart silently again.  Subsection letters (``§6c``) resolve to their
numeric section.  Run by ``make check`` / ``scripts/check.sh``.

    python scripts/check_docs.py [--root <repo root>]
"""
from __future__ import annotations

import argparse
import os
import re
import sys
from typing import Dict, List, Tuple

SCAN_DIRS = ("src", "benchmarks", "examples", "tests", "scripts")
CITE_RE = re.compile(r"DESIGN\.md\s*§(\d+)[a-z]?")
HEADING_RE = re.compile(r"^#{1,3}\s*§(\d+)\b", re.MULTILINE)


def cited_sections(root: str) -> Dict[int, List[Tuple[str, int]]]:
    """section number -> [(relative path, line number), ...]"""
    cites: Dict[int, List[Tuple[str, int]]] = {}
    for d in SCAN_DIRS:
        top = os.path.join(root, d)
        for dirpath, _, files in os.walk(top):
            for name in files:
                if not name.endswith(".py") or name == "check_docs.py":
                    continue
                path = os.path.join(dirpath, name)
                with open(path, encoding="utf-8") as f:
                    for ln, line in enumerate(f, 1):
                        for m in CITE_RE.finditer(line):
                            rel = os.path.relpath(path, root)
                            cites.setdefault(int(m.group(1)), []).append(
                                (rel, ln))
    return cites


def defined_sections(root: str) -> set:
    design = os.path.join(root, "DESIGN.md")
    if not os.path.exists(design):
        return set()
    with open(design, encoding="utf-8") as f:
        return {int(m.group(1)) for m in HEADING_RE.finditer(f.read())}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--root",
                    default=os.path.join(os.path.dirname(__file__), ".."))
    args = ap.parse_args()
    root = os.path.abspath(args.root)

    cites = cited_sections(root)
    have = defined_sections(root)
    if not have:
        print("check_docs: DESIGN.md missing or has no §N headings",
              file=sys.stderr)
        return 1

    missing = {n: locs for n, locs in sorted(cites.items()) if n not in have}
    if missing:
        for n, locs in missing.items():
            print(f"check_docs: DESIGN.md §{n} cited but not defined:",
                  file=sys.stderr)
            for rel, ln in locs:
                print(f"  {rel}:{ln}", file=sys.stderr)
        return 1

    n_cites = sum(len(v) for v in cites.values())
    print(f"check_docs: OK — {n_cites} citations across "
          f"{len(cites)} sections, all defined "
          f"(§{min(have)}..§{max(have)} in DESIGN.md)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
